"""Serving engine tests: MURS HBM-admission vs FAIR under pressure."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.sched import MursConfig
from repro.models import init_model
from repro.serve import EngineConfig, Request, ServingEngine
from repro.serve.kv_cache import (
    PageBlockAllocator,
    PagedKVManager,
    constant_state_bytes,
    kv_bytes_per_token,
)


@pytest.fixture(scope="module")
def small_model():
    cfg = ARCHS["internlm2-1.8b"].smoke()
    params = init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _requests():
    reqs = [Request(f"A{i}", "A", list(range(10, 18)), 40) for i in range(3)]
    reqs += [Request(f"B{i}", "B", list(range(30, 34)), 6) for i in range(4)]
    return reqs


class TestKVManager:
    def test_byte_model_matches_murs_classes(self):
        """The per-arch marginal KV bytes realize the MURS memory models."""
        per_tok = {a: kv_bytes_per_token(ARCHS[a]) for a in ARCHS}
        # mamba2 decode is constant-model: zero marginal bytes
        assert per_tok["mamba2-2.7b"] == 0.0
        # MLA's latent cache is ~57× shallower than its own hypothetical
        # per-head K/V (128 heads × 2 × 128 dims vs kv_lora 512 + rope 64)
        dsv2 = ARCHS["deepseek-v2-236b"]
        per_head_kv = 2 * dsv2.n_kv_heads * dsv2.head_dim * 2 * dsv2.n_layers
        assert per_tok["deepseek-v2-236b"] < 0.05 * per_head_kv
        # mamba has constant state instead
        assert constant_state_bytes(ARCHS["mamba2-2.7b"]) > 0

    def test_paging_accounting(self):
        cfg = ARCHS["internlm2-1.8b"]
        mgr = PagedKVManager(capacity_bytes=1e9, page_tokens=16)
        mgr.register("r1", cfg)
        grew = mgr.grow_to("r1", 17)  # needs 2 pages
        assert grew == pytest.approx(2 * 16 * kv_bytes_per_token(cfg))
        assert mgr.grow_to("r1", 20) == 0.0  # still within 2 pages
        assert mgr.page_table("r1") == (0, 1)
        freed = mgr.release("r1")
        assert freed >= grew
        assert mgr.used_bytes == 0.0
        assert mgr.free_pages == mgr.n_pages


class TestPageBlockAllocator:
    def test_free_list_alloc_and_reuse(self):
        a = PageBlockAllocator(n_pages=4)
        assert a.grow_to("r1", 2) == 2
        assert a.table("r1") == (0, 1)
        assert a.grow_to("r2", 2) == 2
        assert a.table("r2") == (2, 3)
        assert a.free_pages == 0
        a.free("r1")
        assert a.free_pages == 2
        # LIFO reuse: the most recently freed pages come back first
        a.grow_to("r3", 1)
        assert a.table("r3")[0] in (0, 1)

    def test_overflow_pages_and_residency(self):
        a = PageBlockAllocator(n_pages=2)
        a.grow_to("r1", 2)
        assert a.resident("r1")
        a.grow_to("r2", 2)  # pool exhausted → overflow ids
        assert not a.resident("r2")
        assert a.overflow_pages == 2
        assert all(pid >= a.n_pages for pid in a.table("r2"))

    def test_reclaim_pages_overflow_back_in(self):
        a = PageBlockAllocator(n_pages=2)
        a.grow_to("r1", 2)
        a.grow_to("r2", 2)
        a.free("r1")
        moved = a.reclaim()
        assert moved == 2
        assert a.resident("r2")
        assert a.overflow_pages == 0
        assert all(pid < a.n_pages for pid in a.table("r2"))

    def test_table_array_pads_and_bounds(self):
        import numpy as np

        a = PageBlockAllocator(n_pages=8)
        a.grow_to("r1", 3)
        a.grow_to("r2", 1)
        arr = a.table_array(["r1", "r2"], max_pages=4)
        assert arr.shape == (2, 4) and arr.dtype == np.int32
        assert list(arr[0][:3]) == list(a.table("r1"))
        with pytest.raises(ValueError):
            a.table_array(["r1"], max_pages=2)


class TestChunkedPrefill:
    def test_chunked_matches_monolithic_greedy(self, small_model):
        """A long prompt split into chunks must generate the same greedy
        tokens as a monolithic prefill, and a co-resident short request
        must keep decoding while the long prompt chunks through."""
        cfg, params = small_model
        prompt = list(range(5, 25))  # 20 tokens
        outs = {}
        for name, chunk in (("mono", 1000), ("chunk", 6)):
            eng = ServingEngine(
                cfg, params,
                EngineConfig(n_slots=2, max_seq=64, hbm_capacity_bytes=1e12,
                             prefill_chunk_tokens=chunk),
            )
            # short FIRST: it finishes its prefill in tick 0 and then
            # decodes on every tick the long prompt is still chunking —
            # the decode batch genuinely overlaps an in-flight prefill
            eng.submit(Request("short", "U", list(range(3, 7)), 8))
            eng.submit(Request("long", "T", prompt, 8))
            out = eng.run(max_ticks=100).extras
            outs[name] = (
                eng.requests["long"].generated,
                eng.requests["short"].generated,
                out["chunked_prefill_ticks"],
                eng.requests["short"].finish_tick
                < eng.requests["long"].finish_tick,
            )
        assert outs["mono"][0] == outs["chunk"][0]
        assert outs["mono"][1] == outs["chunk"][1]
        assert outs["chunk"][2] > 0 and outs["mono"][2] == 0
        assert outs["chunk"][3], "short request must finish during/ahead"


class TestAdmissionLiveness:
    def test_impossible_prompt_fails_fast(self, small_model):
        """A prompt that can never fit the pool must fail at admission
        (OOM semantics) instead of head-of-line blocking the queue."""
        cfg, params = small_model
        cap = kv_bytes_per_token(cfg) * 32  # 2-page pool
        eng = ServingEngine(
            cfg, params,
            EngineConfig(n_slots=2, max_seq=64, hbm_capacity_bytes=cap),
        )
        eng.submit(Request("huge", "T", list(range(60)), 4))  # 4 pages > pool
        eng.submit(Request("ok", "U", list(range(4)), 4))
        eng.run(max_ticks=200)
        assert eng.requests["huge"].state == "failed"
        assert eng.requests["ok"].state == "done"


class TestEngineUnderPressure:
    @pytest.fixture(scope="class")
    def results(self, small_model):
        cfg, params = small_model
        cap = kv_bytes_per_token(cfg) * 80
        out = {}
        for mode, sched in (("fair", None), ("murs", MursConfig(period=1.0))):
            eng = ServingEngine(
                cfg, params,
                EngineConfig(n_slots=4, max_seq=64,
                             hbm_capacity_bytes=cap, scheduler=sched),
            )
            for r in _requests():
                eng.submit(r)
            out[mode] = eng.run(max_ticks=400).extras
        return out

    def test_fair_spills_under_pressure(self, results):
        """Stock scheduling pays in KV offloads (the TPU 'spill')."""
        assert results["fair"]["offload_events"] > 0

    def test_murs_avoids_spills_entirely(self, results):
        """Paper Table III: MURS reduces spills ~90%; here to zero."""
        assert results["murs"]["offload_events"] == 0

    def test_murs_completes_everything(self, results):
        """Paper §VI-C: MURS keeps serving where the baseline OOMs."""
        assert results["murs"]["failed"] == 0
        assert results["murs"]["completed"] == 7

    def test_murs_uses_suspension(self, results):
        assert results["murs"]["suspensions"] > 0

    def test_fair_hard_fails_when_offload_unavailable(self, small_model):
        """With no spill path (offload disabled), the stock scheduler throws
        the OOM analogue and fails requests; MURS still completes all —
        the paper's Fig 5 OME scenario."""
        cfg, params = small_model
        cap = kv_bytes_per_token(cfg) * 80
        out = {}
        for mode, sched in (("fair", None), ("murs", MursConfig(period=1.0))):
            eng = ServingEngine(
                cfg, params,
                EngineConfig(n_slots=4, max_seq=64, hbm_capacity_bytes=cap,
                             scheduler=sched, offload_enabled=False),
            )
            for r in _requests():
                eng.submit(r)
            out[mode] = eng.run(max_ticks=400).extras
        assert out["fair"]["failed"] > 0
        assert out["murs"]["failed"] == 0
        assert out["murs"]["completed"] == 7

    def test_no_pressure_no_interference(self, small_model):
        """With ample capacity MURS must not suspend anything."""
        cfg, params = small_model
        cap = kv_bytes_per_token(cfg) * 100000
        eng = ServingEngine(
            cfg, params,
            EngineConfig(n_slots=4, max_seq=64, hbm_capacity_bytes=cap,
                         scheduler=MursConfig(period=1.0)),
        )
        for r in _requests():
            eng.submit(r)
        out = eng.run(max_ticks=400).extras
        assert out["failed"] == 0
        assert out["suspensions"] == 0
        assert out["completed"] == 7


class TestDecodedTokensMatchUnbatchedReference(object):
    def test_engine_decode_matches_direct_decode(self, small_model):
        """Slot-batched engine decode must equal a direct single-request
        prefill+decode loop (greedy tokens identical)."""
        cfg, params = small_model
        from repro.models import decode_step, prefill

        prompt = list(range(10, 18))
        gen = 6
        # direct reference
        tokens = jnp.asarray(prompt, jnp.int32)[None]
        logits, caches = prefill(cfg, params, tokens, max_seq=64, remat=False)
        out_ref = [int(jnp.argmax(logits[0, -1]))]
        pos = len(prompt)
        for _ in range(gen - 1):
            logits, caches = decode_step(
                cfg, params,
                jnp.asarray([[out_ref[-1]]], jnp.int32), caches,
                jnp.int32(pos),
            )
            out_ref.append(int(jnp.argmax(logits[0, 0])))
            pos += 1
        # engine
        eng = ServingEngine(
            cfg, params,
            EngineConfig(n_slots=2, max_seq=64, hbm_capacity_bytes=1e12),
        )
        eng.submit(Request("r", "T", prompt, gen))
        eng.run(max_ticks=100)
        assert eng.requests["r"].generated[:gen] == out_ref


class TestMetricPopulations:
    def test_ttft_counts_only_completed_requests(self, small_model):
        """Regression: ttft_ticks used to include failed requests (any
        first_token_tick >= 0) while latency_ticks counted only
        state == "done" — under shedding the two percentile populations
        silently diverged.  Both now describe completed requests;
        failed-request TTFT is reported separately."""
        cfg, params = small_model
        eng = ServingEngine(
            cfg, params,
            EngineConfig(n_slots=2, max_seq=64, hbm_capacity_bytes=1e12),
        )
        eng.submit(Request("ok", "T", list(range(4)), 4))
        out = eng.run(max_ticks=100).extras
        assert len(out["ttft_ticks"]) == 1
        assert out["ttft_failed_ticks"] == []
        # a request that produced a first token and then failed must land
        # in the failed population, not the SLO one
        shed = Request("shed", "T", [1, 2], 4, submit_tick=0)
        shed.state = "failed"
        shed.first_token_tick = 7
        eng.requests["shed"] = shed
        out = eng.run(max_ticks=eng.tick).extras
        assert len(out["ttft_ticks"]) == 1
        assert out["ttft_failed_ticks"] == [7]
        assert len(out["ttft_ticks"]) == len(out["latency_ticks"])


class TestMemoryModelClassification:
    def test_decode_classifies_per_murs_models(self, small_model):
        """§III live: attention decodes classify LINEAR (KV grows per
        token); the classification is measured online by the sampler."""
        cfg, params = small_model
        cap = kv_bytes_per_token(cfg) * 1e6  # no pressure needed here
        eng = ServingEngine(
            cfg, params,
            EngineConfig(n_slots=2, max_seq=64, hbm_capacity_bytes=cap,
                         scheduler=MursConfig(period=1.0)),
        )
        eng.submit(Request("r", "T", list(range(8)), 20))
        out = eng.run(max_ticks=200).extras
        assert out["memory_models"]["r"] == "linear"

    def test_fair_offloads_murs_avoids(self, small_model):
        """Table III live analogue: the stock scheduler spills (offloads KV
        to host) under pressure; MURS suspension avoids it."""
        cfg, params = small_model
        cap = kv_bytes_per_token(cfg) * 90
        counts = {}
        for mode, sched in (("fair", None), ("murs", MursConfig(period=1.0))):
            eng = ServingEngine(
                cfg, params,
                EngineConfig(n_slots=4, max_seq=64, hbm_capacity_bytes=cap,
                             scheduler=sched),
            )
            reqs = [Request(f"A{i}", "A", list(range(10, 18)), 30)
                    for i in range(3)]
            reqs += [Request(f"B{i}", "B", list(range(30, 34)), 6)
                     for i in range(2)]
            for r in reqs:
                eng.submit(r)
            out = eng.run(max_ticks=600).extras
            counts[mode] = out
        assert counts["fair"]["offload_events"] > 0
        assert (
            counts["murs"]["offload_events"]
            < counts["fair"]["offload_events"]
        )
