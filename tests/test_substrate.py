"""Substrate tests: optimizer, checkpointing, compression, fault tolerance,
data pipeline, sharding rules."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint.checkpointing import (
    AsyncCheckpointer,
    latest_step_path,
    restore,
    save,
)
from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, DataPipeline, _host_batch
from repro.dist import compression
from repro.dist.fault import RestartManager, StragglerDetector
from repro.dist.sharding import make_rules, param_spec_for_path
from repro.optim import adamw


# --------------------------------------------------------------- optimizer
class TestAdamW:
    def test_reduces_quadratic(self):
        cfg = adamw.AdamWConfig(
            lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=100,
            min_lr_ratio=1.0,
        )
        params = {"w": jnp.array([3.0, -2.0])}
        state = adamw.init(params)
        for _ in range(60):
            grads = {"w": 2 * params["w"]}  # d/dw of w²
            params, state, _ = adamw.update(cfg, grads, state, params)
        assert float(jnp.abs(params["w"]).max()) < 0.3

    def test_clip_bounds_update(self):
        cfg = adamw.AdamWConfig(lr=1.0, clip_norm=1.0, warmup_steps=0)
        params = {"w": jnp.zeros(4)}
        state = adamw.init(params)
        grads = {"w": jnp.full(4, 1e6)}
        _, _, gnorm = adamw.update(cfg, grads, state, params)
        assert float(gnorm) == pytest.approx(2e6, rel=1e-3)

    def test_schedule_shape(self):
        cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                                min_lr_ratio=0.1)
        lrs = [float(adamw.schedule(cfg, jnp.int32(s))) for s in (0, 5, 10, 100)]
        assert lrs[0] == 0.0
        assert lrs[1] == pytest.approx(0.5)
        assert lrs[2] == pytest.approx(1.0)
        assert lrs[3] == pytest.approx(0.1, rel=1e-2)


# ------------------------------------------------------------- checkpoints
class TestCheckpointing:
    def _tree(self, key):
        return {
            "a": jax.random.normal(key, (8, 4), jnp.float32),
            "b": {"c": jax.random.normal(key, (3,), jnp.bfloat16)},
            "step": jnp.int32(7),
        }

    def test_roundtrip(self):
        tree = self._tree(jax.random.PRNGKey(0))
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "ckpt_5.ckpt")
            save(path, tree, step=5)
            restored, step = restore(path, tree)
            assert step == 5
            for x, y in zip(
                jax.tree_util.tree_leaves(tree),
                jax.tree_util.tree_leaves(restored),
            ):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_atomicity_and_latest(self):
        tree = self._tree(jax.random.PRNGKey(1))
        with tempfile.TemporaryDirectory() as d:
            for s in (5, 20, 10):
                save(os.path.join(d, f"ckpt_{s}.ckpt"), tree, step=s)
            assert latest_step_path(d).endswith("ckpt_20.ckpt")
            assert not [f for f in os.listdir(d) if f.endswith(".tmp")]

    def test_async_checkpointer(self):
        tree = self._tree(jax.random.PRNGKey(2))
        with tempfile.TemporaryDirectory() as d:
            ck = AsyncCheckpointer()
            path = os.path.join(d, "ckpt_1.ckpt")
            ck.save(path, tree, step=1)
            ck.wait()
            restored, step = restore(path, tree)
            assert step == 1

    def test_structure_mismatch_raises(self):
        tree = self._tree(jax.random.PRNGKey(3))
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "ckpt_1.ckpt")
            save(path, tree, step=1)
            with pytest.raises(ValueError, match="structure mismatch"):
                restore(path, {"only": tree["a"]})


# ------------------------------------------------------------- compression
class TestGradCompression:
    @given(scale=st.floats(1e-3, 1e3))
    @settings(max_examples=20, deadline=None)
    def test_quantize_bounded_error(self, scale):
        x = jnp.linspace(-scale, scale, 64)
        q, s = compression.quantize(x)
        err = jnp.abs(compression.dequantize(q, s) - x).max()
        assert float(err) <= float(s) * 0.5 + 1e-9

    def test_error_feedback_converges(self):
        """EF carries the residual: the *sum* of compressed grads tracks the
        sum of true grads (bias-free in the limit)."""
        params = {"w": jnp.zeros(16)}
        ef = compression.init(params)
        true_sum = jnp.zeros(16)
        comp_sum = jnp.zeros(16)
        key = jax.random.PRNGKey(0)
        for i in range(50):
            key, k = jax.random.split(key)
            g = {"w": jax.random.normal(k, (16,)) * 0.01}
            true_sum = true_sum + g["w"]
            deq, ef, _ = compression.compress_grads(g, ef)
            comp_sum = comp_sum + deq["w"]
        # residual bound: one quantization step of the last grad
        assert float(jnp.abs(true_sum - comp_sum).max()) < 5e-3


# ---------------------------------------------------------- fault handling
class TestFaultTolerance:
    def test_straggler_detection(self):
        det = StragglerDetector(min_samples=3)
        for _ in range(5):
            det.observe("h0", 1.0)
            det.observe("h1", 1.05)
            det.observe("h2", 2.5)
        assert det.stragglers() == ["h2"]
        w = det.rebalance_weights()
        assert w["h2"] < w["h0"]
        assert abs(sum(w.values()) - 1.0) < 1e-9

    def test_restart_manager_resume(self):
        with tempfile.TemporaryDirectory() as d:
            tree = {"w": jnp.arange(4.0)}
            save(os.path.join(d, "ckpt_3.ckpt"), tree, step=3)
            rm = RestartManager(d)
            restored, step = rm.resume(tree)
            assert step == 3
            np.testing.assert_array_equal(
                np.asarray(restored["w"]), np.arange(4.0)
            )

    def test_backoff_and_retry_budget(self):
        rm = RestartManager("/tmp/none", max_retries=2, backoff_s=1.0)
        assert rm.should_retry()
        assert rm.on_failure(RuntimeError()) == 1.0
        assert rm.on_failure(RuntimeError()) == 2.0
        assert not rm.should_retry()
        rm.on_success()
        assert rm.should_retry()

    def test_backoff_is_capped(self):
        """Regression: an uncapped 2**n backoff reaches hour-scale sleeps
        in a long preemption loop; max_backoff_s is the ceiling."""
        rm = RestartManager(
            "/tmp/none", max_retries=10, backoff_s=1.0, max_backoff_s=8.0
        )
        delays = [rm.on_failure(RuntimeError()) for _ in range(6)]
        assert delays == [1.0, 2.0, 4.0, 8.0, 8.0, 8.0]
        with pytest.raises(ValueError):
            RestartManager("/tmp/none", backoff_s=4.0, max_backoff_s=2.0)

    def test_rebalance_weights_honors_min_samples(self):
        """Regression: rebalance_weights used to average over hosts with
        ANY samples, so one noisy first observation skewed the whole
        weight vector.  It now reuses the min_samples-gated means that
        stragglers() honors; an under-sampled host gets the neutral
        (uniform) share instead of a speed penalty."""
        det = StragglerDetector(min_samples=3)
        for _ in range(5):
            det.observe("h0", 1.0)
            det.observe("h1", 1.0)
        det.observe("noisy", 100.0)  # one sample: no trustworthy mean yet
        w = det.rebalance_weights()
        assert w["noisy"] == pytest.approx(1.0 / 3.0)
        assert w["h0"] == pytest.approx(w["h1"]) == pytest.approx(1.0 / 3.0)

    def test_rebalance_weights_all_hosts_fallback(self):
        """Nobody has min_samples yet → explicit uniform fallback over
        every observed host (not an empty dict, not a skewed one)."""
        det = StragglerDetector(min_samples=5)
        det.observe("a", 1.0)
        det.observe("b", 9.0)
        w = det.rebalance_weights()
        assert w == {"a": 0.5, "b": 0.5}
        assert det.rebalance_weights() == w  # stable until samples accrue


# ------------------------------------------------------------ data pipeline
class TestDataPipeline:
    def test_determinism_and_shapes(self):
        cfg = ARCHS["internlm2-1.8b"].smoke()
        shape = ShapeConfig("t", 16, 4, "train")
        a = _host_batch(cfg, shape, DataConfig(seed=1), step=3)
        b = _host_batch(cfg, shape, DataConfig(seed=1), step=3)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        assert a["tokens"].shape == (4, 16)
        assert (a["labels"][:, :-1] == a["tokens"][:, 1:]).all()
        assert (a["labels"][:, -1] == -1).all()

    def test_prefetch_iterator(self):
        cfg = ARCHS["internlm2-1.8b"].smoke()
        shape = ShapeConfig("t", 8, 2, "train")
        pipe = DataPipeline(cfg, shape, DataConfig(prefetch=2))
        try:
            b1 = next(pipe)
            b2 = next(pipe)
            assert b1["tokens"].shape == (2, 8)
            assert not np.array_equal(
                np.asarray(b1["tokens"]), np.asarray(b2["tokens"])
            )
        finally:
            pipe.close()


# ---------------------------------------------------------------- sharding
class TestShardingRules:
    def test_param_rules_resolve(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = make_rules(mesh)
        spec = param_spec_for_path("layers/b0/attn/wq", rules, 3)
        assert spec == jax.sharding.PartitionSpec(None, "data", "model")
        spec = param_spec_for_path("embed/tokens", rules, 2)
        assert spec == jax.sharding.PartitionSpec("model", "data")
        # unknown path → replicated
        assert param_spec_for_path("final_ln", rules, 1) == jax.sharding.PartitionSpec()

    def test_mesh_axis_dedup(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = make_rules(mesh)
        # two logical axes mapping to the same mesh axis: second gets None
        spec = rules.spec(("heads", "mlp"))
        assert spec == jax.sharding.PartitionSpec("model", None)


# ------------------------------------------------------- elastic resharding
class TestElasticReshard:
    def test_checkpoint_restores_across_mesh_layouts(self):
        """A checkpoint written under one sharding restores onto another
        (grow/shrink) — the restore path is host-side + device_put with the
        CURRENT rules, so topology changes are transparent."""
        from repro.dist.fault import elastic_reshard

        tree = {
            "layers": {
                "b0": {"mlp": {"gate": jnp.arange(64.0).reshape(8, 8)}}
            },
            "final_ln": jnp.ones(8),
        }
        with tempfile.TemporaryDirectory() as d:
            save(os.path.join(d, "ckpt_1.ckpt"), tree, step=1)
            restored, _ = restore(os.path.join(d, "ckpt_1.ckpt"), tree)
            mesh = jax.make_mesh((1, 1), ("data", "model"))
            rules = make_rules(mesh)
            resharded = elastic_reshard(restored, rules)
            np.testing.assert_array_equal(
                np.asarray(resharded["layers"]["b0"]["mlp"]["gate"]),
                np.asarray(tree["layers"]["b0"]["mlp"]["gate"]),
            )
            # the gate got the mlp rule (fsdp→data, mlp→model)
            spec = resharded["layers"]["b0"]["mlp"]["gate"].sharding.spec
            assert spec == jax.sharding.PartitionSpec("data", "model")


# --------------------------------------------- pressure-adaptive microbatch
class TestPressureAdaptiveAccumulator:
    def _make(self, readings):
        from repro.sched import MursConfig
        from repro.train.pressure import PressureAdaptiveAccumulator

        it = iter(readings)
        return PressureAdaptiveAccumulator(
            probe=lambda: next(it), config=MursConfig(), patience=2
        )

    def test_red_doubles_immediately(self):
        acc = self._make([0.85, 0.85])
        assert acc.step() == 2
        assert acc.step() == 4

    def test_yellow_needs_patience(self):
        acc = self._make([0.5, 0.5, 0.5])
        assert acc.step() == 1  # hot 1
        assert acc.step() == 2  # hot 2 → double
        assert acc.step() == 1 or acc.factor == 2  # stays until cool

    def test_cool_halves_back(self):
        acc = self._make([0.85, 0.1, 0.1, 0.1, 0.1])
        assert acc.step() == 2
        acc.step()
        assert acc.step() == 1  # two cool steps → halve

    def test_bounds_respected(self):
        acc = self._make([0.9] * 12 + [0.05] * 30)
        for _ in range(12):
            acc.step()
        assert acc.factor <= acc.max_factor
        for _ in range(30):
            acc.step()
        assert acc.factor >= acc.min_factor

    @given(st.lists(st.floats(0.0, 1.0), min_size=1, max_size=60))
    @settings(max_examples=40, deadline=None)
    def test_factor_always_power_of_two_in_bounds(self, readings):
        acc = self._make(readings + [0.0])  # probe has enough values
        for _ in range(len(readings)):
            f = acc.step()
            assert acc.min_factor <= f <= acc.max_factor
            assert f & (f - 1) == 0  # power of two


class TestAdaptiveTrainer:
    def test_trainer_adapts_microbatching_under_pressure(self):
        """End-to-end: a rising pressure probe makes the trainer re-jit
        with a larger accumulation factor mid-run, and training proceeds."""
        import tempfile

        from repro.configs import ARCHS
        from repro.optim.adamw import AdamWConfig
        from repro.train import Trainer, TrainerConfig

        readings = iter([0.1, 0.1, 0.9, 0.9, 0.9, 0.9, 0.9, 0.9, 0.9, 0.9])
        cfg = ARCHS["internlm2-1.8b"].smoke()
        shape = ShapeConfig("t", 16, 4, "train")
        with tempfile.TemporaryDirectory() as d:
            t = Trainer(
                cfg, shape,
                TrainerConfig(
                    steps=8, ckpt_dir=d, ckpt_every=100, log_every=1,
                    hbm_probe=lambda: next(readings, 0.9),
                    opt=AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=8),
                ),
            )
            out = t.run()
        assert out["final_step"] == 8
        factors = [h["factor"] for h in t._adaptive.history]
        assert factors[0] == 1
        assert max(factors) >= 2, "pressure must raise the accumulation factor"
