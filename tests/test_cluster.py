"""ServingCluster tests: routing, straggler migration, crash recovery.

The fault substrate (`repro.dist.fault`) finally runs in the SERVING
path here: StragglerDetector over replica tick-service-times, live KV
migration over a modeled network link, and RestartManager-style bounded
retry for replica crashes — plus a hypothesis property pinning the
cluster's core accounting invariant (no request lost or duplicated under
arbitrary submit/migrate/crash interleavings).
"""

import jax
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import ARCHS
from repro.dist.fault import StragglerDetector
from repro.models import init_model
from repro.sched import (
    FairPolicy,
    MursConfig,
    MursPolicy,
    PriorityConfig,
    PriorityPolicy,
)
from repro.serve import (
    ClusterConfig,
    EngineConfig,
    Request,
    ServingCluster,
    ServingEngine,
)
from repro.serve.kv_cache import kv_bytes_per_token


@pytest.fixture(scope="module")
def small_model():
    cfg = ARCHS["internlm2-1.8b"].smoke()
    params = init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine_factory(cfg, tokens=80, n_slots=3, murs=True):
    cap = kv_bytes_per_token(cfg) * tokens

    def make():
        policy = (
            MursPolicy(MursConfig.for_serving(period=1.0))
            if murs
            else FairPolicy()
        )
        return EngineConfig(
            n_slots=n_slots, max_seq=64, hbm_capacity_bytes=cap,
            policy=policy,
        )

    return make


# ------------------------------------------------------------ placement hook
class TestPlacementScore:
    STATS_LOW = {"demand_fraction": 0.1, "slot_load": 0.2}
    STATS_HIGH = {"demand_fraction": 0.9, "slot_load": 1.5}

    def test_fair_scores_every_replica_equal(self):
        p = FairPolicy()
        assert p.placement_score("A", self.STATS_LOW) == 0.0
        assert p.placement_score("A", self.STATS_HIGH) == 0.0

    def test_murs_prefers_low_load(self):
        p = MursPolicy(MursConfig.for_serving())
        assert p.placement_score("A", self.STATS_LOW) > p.placement_score(
            "A", self.STATS_HIGH
        )

    def test_murs_rate_ema_blends_demand_vs_slots(self):
        """A high-usage-rate tenant is routed by byte demand; a low-rate
        tenant by slot occupancy — the §III classes applied across
        replicas."""
        p = MursPolicy(MursConfig.for_serving())
        p.note_group_rate("heavy", 100.0, now=0.0)
        p.note_group_rate("light", 0.0, now=0.0)
        demand_heavy = {"demand_fraction": 0.9, "slot_load": 0.0}
        slots_heavy = {"demand_fraction": 0.0, "slot_load": 0.9}
        # the heavy tenant avoids the demand-loaded replica most
        assert p.placement_score("heavy", demand_heavy) < p.placement_score(
            "heavy", slots_heavy
        )
        # the light tenant avoids the slot-loaded replica most
        assert p.placement_score("light", slots_heavy) < p.placement_score(
            "light", demand_heavy
        )
        assert p.group_rates()["heavy"] > p.group_rates()["light"]

    def test_priority_weight_divides_aversion(self):
        p = PriorityPolicy(PriorityConfig(weights={"vip": 4.0, "low": 1.0}))
        # same replica load: the vip's score is closer to zero, so on a
        # contended best-first routing pass it claims the replica first
        assert p.placement_score("vip", self.STATS_HIGH) > p.placement_score(
            "low", self.STATS_HIGH
        )


# ---------------------------------------------------------------- routing
class TestRouting:
    def test_fair_router_round_robins(self, small_model):
        cfg, params = small_model
        cl = ServingCluster(
            cfg, params,
            ClusterConfig(
                engine=_engine_factory(cfg, murs=False), n_replicas=2,
                router=FairPolicy(),
            ),
        )
        for i in range(4):
            cl.submit(Request(f"r{i}", "T", list(range(4)), 4))
        cl.step()
        homes = [cl._home[f"r{i}"] for i in range(4)]
        assert sorted(homes) == [0, 0, 1, 1]
        assert homes[0] != homes[1]  # alternating, not blocked

    def test_murs_router_balances_heavy_requests(self, small_model):
        """Round-robin packs the heavy (even-position) requests onto one
        replica; demand-aware routing splits them."""
        cfg, params = small_model
        heavy = [
            Request(f"H{i}", "A", list(range(10, 18)), 40) for i in range(2)
        ]
        light = [
            Request(f"L{i}", "B", list(range(30, 34)), 4) for i in range(2)
        ]
        # interleave H,L,H,L — round-robin would pair the two heavies
        stream = [heavy[0], light[0], heavy[1], light[1]]
        cl = ServingCluster(
            cfg, params,
            ClusterConfig(
                engine=_engine_factory(cfg), n_replicas=2,
                router=MursPolicy(MursConfig.for_serving()),
            ),
        )
        for r in stream:
            cl.submit(r)
        cl.step()
        assert cl._home["H0"] != cl._home["H1"]


# ----------------------------------------------------- straggler detection
class TestStragglerPass:
    def test_detector_over_synthetic_replica_tick_times(self):
        """The serving-path wiring consumes the detector exactly as the
        trainer does: per-replica observations, median-ratio flagging."""
        det = StragglerDetector(min_samples=4, ratio=1.5)
        for _ in range(6):
            det.observe("r0", 1.1)
            det.observe("r1", 1.0)
            det.observe("r2", 5.0)  # the throttled replica
        assert det.stragglers() == ["r2"]
        det.forget("r2")  # the cluster's restart path
        assert det.stragglers() == []

    def test_straggler_triggers_live_migration(self, small_model):
        cfg, params = small_model
        cl = ServingCluster(
            cfg, params,
            ClusterConfig(
                engine=_engine_factory(cfg), n_replicas=2,
                router=MursPolicy(MursConfig.for_serving()),
                straggler_min_samples=4,
            ),
        )
        for i in range(4):
            cl.submit(Request(f"A{i}", "A", list(range(10, 18)), 24))
        cl.set_slowdown(0, 8.0)
        out = cl.run(max_ticks=400).extras
        assert out["straggler_flags"] >= 1
        assert out["migrations"]["completed"] >= 1
        assert out["completed"] == 4 and out["failed"] == 0

    def test_flagged_straggler_never_receives_work(self, small_model):
        """Regression: delivery/routing used to exclude only the
        migration SOURCE — a victim could land on (and new work route
        onto) another replica the detector had already flagged."""
        cfg, params = small_model
        cl = ServingCluster(
            cfg, params,
            ClusterConfig(
                engine=_engine_factory(cfg), n_replicas=3,
                straggler_min_samples=4,
            ),
        )
        # flag r1: slow against the r0/r2 median
        for _ in range(6):
            cl.detector.observe("r0", 1.0)
            cl.detector.observe("r1", 9.0)
            cl.detector.observe("r2", 1.0)
        assert cl._flagged_indices() == {1}
        # migration delivery from r0 must skip flagged r1
        for _ in range(8):
            assert cl._pick_target("T", exclude={0} | cl._flagged_indices()) == 2
        # fresh submissions route around the straggler too
        for i in range(4):
            cl.submit(Request(f"s{i}", "T", list(range(4)), 4))
        cl._route()
        assert all(cl._home[f"s{i}"] != 1 for i in range(4))

    def test_no_migration_without_straggler(self, small_model):
        cfg, params = small_model
        cl = ServingCluster(
            cfg, params,
            ClusterConfig(
                engine=_engine_factory(cfg), n_replicas=2,
                straggler_min_samples=4,
            ),
        )
        for i in range(4):
            cl.submit(Request(f"A{i}", "A", list(range(10, 18)), 12))
        out = cl.run(max_ticks=400).extras
        assert out["migrations"]["started"] == 0
        assert out["completed"] == 4


# ------------------------------------------------------- migration fidelity
class TestMigrationRoundTrip:
    def test_mid_decode_migration_identical_tokens(self, small_model):
        """The headline invariant: extract → wire → install continues the
        request with IDENTICAL greedy tokens (the slot-cache subtree is
        bit-exact), and the byte accounting is conserved end to end."""
        cfg, params = small_model
        make = _engine_factory(cfg, tokens=200, n_slots=2)
        ref = ServingEngine(cfg, params, make())
        ref.submit(Request("r", "T", list(range(10, 18)), 16))
        ref.run(max_ticks=200)
        ref_tokens = list(ref.requests["r"].generated)

        cl = ServingCluster(
            cfg, params, ClusterConfig(engine=make, n_replicas=2)
        )
        cl.submit(Request("r", "T", list(range(10, 18)), 16))
        for _ in range(6):
            cl.step()
        src = cl._home["r"]
        src_bytes = cl.replicas[src].kv.request_bytes("r")
        assert src_bytes > 0
        assert cl.migrate("r", src)
        # the source forgot the request entirely — no double accounting
        assert "r" not in cl.replicas[src].requests
        assert cl.replicas[src].kv.request_bytes("r") == 0.0
        ticket, _ = cl._inflight["r"]
        assert ticket.raw_bytes == pytest.approx(src_bytes)
        assert 0 < ticket.wire_bytes < ticket.raw_bytes  # compressed wire
        out = cl.run(max_ticks=300).extras
        tgt = cl._home["r"]
        assert tgt != src
        tgt_req = cl.replicas[tgt].requests["r"]
        assert tgt_req.state == "done"
        assert list(tgt_req.generated) == ref_tokens
        # bytes conserved: the target re-materialized the same pages
        assert out["migrations"] == {
            "started": 1, "completed": 1,
            "raw_bytes": pytest.approx(src_bytes),
            "wire_bytes": pytest.approx(ticket.wire_bytes),
        }

    def test_suspended_request_migrates_and_completes(self, small_model):
        """A slotless (suspended) victim replays on the target — same
        tokens, nothing lost."""
        cfg, params = small_model
        make = _engine_factory(cfg, tokens=60, n_slots=2)
        cl = ServingCluster(
            cfg, params,
            ClusterConfig(
                engine=make, n_replicas=2,
                router=MursPolicy(MursConfig.for_serving()),
            ),
        )
        # enough pressure that the replica policy suspends someone
        for i in range(3):
            cl.submit(Request(f"A{i}", "A", list(range(10, 18)), 24))
        suspended = None
        for _ in range(60):
            cl.step()
            for i, eng in enumerate(cl.replicas):
                for r in eng._live.values():
                    if r.state in ("suspended", "offloaded"):
                        suspended = (r.request_id, i)
                        break
                if suspended:
                    break
            if suspended:
                break
        assert suspended is not None, "pressure never suspended anyone"
        rid, src = suspended
        assert cl.migrate(rid, src)
        out = cl.run(max_ticks=500).extras
        assert out["completed"] == 3 and out["failed"] == 0
        tgt = cl._home[rid]
        assert cl.replicas[tgt].requests[rid].state == "done"

    def test_queued_request_migrates_for_free(self, small_model):
        cfg, params = small_model
        make = _engine_factory(cfg, tokens=200, n_slots=1)
        cl = ServingCluster(
            cfg, params, ClusterConfig(engine=make, n_replicas=2)
        )
        for i in range(4):
            cl.submit(Request(f"q{i}", "T", list(range(4)), 4))
        cl.step()
        # one slot per replica: each replica has one queued request —
        # migrating it ships zero KV bytes (nothing materialized yet)
        victims = cl.replicas[0].migratable_requests()
        rid, state = victims[0]
        assert state == "queued"
        assert cl.migrate(rid, 0)
        ticket, _ = cl._inflight[rid]
        assert ticket.wire_bytes == 0.0 and ticket.raw_bytes == 0.0
        out = cl.run(max_ticks=300).extras
        assert out["completed"] == 4


# ----------------------------------------------------------- crash recovery
class TestCrashRecovery:
    def test_crash_requeues_and_completes_everything(self, small_model):
        cfg, params = small_model
        make = _engine_factory(cfg, tokens=80, n_slots=3)
        cl = ServingCluster(
            cfg, params,
            ClusterConfig(
                engine=make, n_replicas=2, max_retries=3,
                retry_backoff_ticks=1.0, max_backoff_ticks=4.0,
            ),
        )
        for i in range(4):
            cl.submit(Request(f"C{i}", "A", list(range(10, 18)), 10))
        for _ in range(6):
            cl.step()
        requeued = cl.crash_replica(0)
        assert requeued > 0
        out = cl.run(max_ticks=600).extras
        assert out["completed"] == 4
        assert out["failed"] == 0 and out["lost"] == 0
        assert out["crashes"] == 1 and out["requeued"] == requeued

    def test_crash_counts_only_delivered_tokens(self, small_model):
        """Regression: a requeued victim's pre-crash tokens die with the
        KV and are regenerated elsewhere — counting them inflated the
        gated cluster throughput above what was actually served."""
        cfg, params = small_model
        make = _engine_factory(cfg, tokens=200, n_slots=2)
        cl = ServingCluster(
            cfg, params, ClusterConfig(engine=make, n_replicas=1)
        )
        cl.submit(Request("x", "T", list(range(8)), 12))
        for _ in range(6):
            cl.step()
        pre = len(cl.replicas[0].requests["x"].generated)
        assert pre > 0  # it really did generate before the crash
        cl.crash_replica(0)
        out = cl.run(max_ticks=300).extras
        assert out["completed"] == 1
        assert out["tokens_generated"] == 12  # not 12 + pre

    def test_retry_budget_exhaustion_is_accounted_not_silent(
        self, small_model
    ):
        cfg, params = small_model
        make = _engine_factory(cfg, tokens=80, n_slots=2)
        cl = ServingCluster(
            cfg, params,
            ClusterConfig(
                engine=make, n_replicas=1, max_retries=1,
                retry_backoff_ticks=1.0, max_backoff_ticks=2.0,
            ),
        )
        cl.submit(Request("x", "T", list(range(8)), 30))
        for _ in range(3):
            cl.step()
        cl.crash_replica(0)  # retry 1/1: requeued
        for _ in range(4):
            cl.step()
        cl.crash_replica(0)  # budget exhausted: lost, recorded as failed
        out = cl.run(max_ticks=200).extras
        assert out["lost"] == 1
        assert out["failed"] == 1
        assert out["completed"] == 0
        assert "x" in cl.failed


# --------------------------------------------------- accounting invariants
class TestNoLossNoDuplication:
    @settings(max_examples=6, deadline=None)
    @given(
        ops=st.lists(
            st.tuples(
                st.sampled_from(["step", "migrate", "crash", "submit"]),
                st.integers(min_value=0, max_value=5),
            ),
            min_size=4,
            max_size=14,
        )
    )
    def test_random_submit_migrate_crash_stream(self, small_model, ops):
        """Whatever the interleaving of submits, forced migrations, and
        replica crashes: every submitted request ends terminal exactly
        once (completed or failed/lost), on exactly one replica — no
        request is lost in flight, none is duplicated across replicas."""
        cfg, params = small_model
        make = _engine_factory(cfg, tokens=60, n_slots=2)
        cl = ServingCluster(
            cfg, params,
            ClusterConfig(
                engine=make, n_replicas=2, max_retries=2,
                retry_backoff_ticks=1.0, max_backoff_ticks=2.0,
                straggler_min_samples=4,
            ),
        )
        submitted = []
        n_crashes = 0
        for kind, arg in ops:
            if kind == "submit" and len(submitted) < 5:
                rid = f"q{len(submitted)}"
                submitted.append(rid)
                cl.submit(Request(rid, f"T{arg % 2}", list(range(6)), 6))
            elif kind == "step":
                for _ in range(1 + arg % 3):
                    cl.step()
            elif kind == "migrate":
                src = arg % 2
                victims = cl.replicas[src].migratable_requests()
                if victims:
                    cl.migrate(victims[arg % len(victims)][0], src)
            elif kind == "crash" and n_crashes < 2:
                n_crashes += 1
                cl.crash_replica(arg % 2)
        out = cl.run(max_ticks=500).extras
        assert out["in_flight_unfinished"] == 0
        # terminal exactly once, somewhere
        terminal = sorted(cl.completed + cl.failed)
        assert terminal == sorted(submitted)
        # no rid lives on two replicas at once
        for rid in submitted:
            holders = [
                i
                for i, eng in enumerate(cl.replicas)
                if rid in eng.requests
            ]
            assert len(holders) <= 1
