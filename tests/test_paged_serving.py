"""Kernel-backed decode parity: the paged Pallas hot path vs the dense
differential oracle.

The paged path gathers pool-layout K/V from the per-slot dense caches
through live page tables and runs ONE ``paged_decode_attention`` call per
layer; ``paged_decode=False`` keeps the original per-slot dense
``decode_step`` as the oracle (the same pattern ``legacy_bookkeeping``
uses for scheduler state).  Greedy argmax tokens must be BIT-identical
between the two across a multi-tenant run that exercises suspends,
resumes, and prefix-cache hits — any drift means the gather, the RoPE
positions, or the kernel's online softmax disagrees with the oracle.
"""

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import init_model, paged_decode_supported
from repro.roofline.analysis import tick_cost_model
from repro.sched import MursConfig, MursPolicy
from repro.serve import EngineConfig, Request, ServingEngine
from repro.serve.kv_cache import PagedKVManager, kv_bytes_per_token


@pytest.fixture(scope="module")
def small_model():
    cfg = ARCHS["internlm2-1.8b"].smoke()
    params = init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _pressure_requests():
    """Multi-tenant mix with a shared prompt prefix: the three C
    requests share their first 16 tokens (one full page — the trie's
    match granularity) so later ones hit the prefix cache, and the pool
    is sized so the heavies force suspends/resumes."""
    reqs = [Request(f"A{i}", "A", list(range(10, 18)), 24) for i in range(2)]
    reqs += [Request(f"B{i}", "B", list(range(30, 34)), 6) for i in range(3)]
    shared = list(range(50, 66))
    reqs += [Request(f"C{i}", "C", shared + [90 + i], 8) for i in range(3)]
    return reqs


def _run_engine(cfg, params, *, paged: bool) -> ServingEngine:
    cap = kv_bytes_per_token(cfg) * 16 * 6  # 6-page pool: forces suspends
    eng = ServingEngine(
        cfg, params,
        EngineConfig(
            n_slots=3, max_seq=64, hbm_capacity_bytes=cap,
            policy=MursPolicy(MursConfig.for_serving(period=1.0)),
            paged_decode=paged,
        ),
    )
    for req in _pressure_requests():
        eng.submit(req)
    eng.run(max_ticks=600)
    return eng


class TestDecodeParity:
    def test_smoke_arch_is_eligible(self):
        assert paged_decode_supported(ARCHS["internlm2-1.8b"].smoke())

    def test_mla_arch_is_not(self):
        assert not paged_decode_supported(ARCHS["deepseek-v2-236b"].smoke())

    def test_greedy_tokens_bit_identical_under_pressure(self, small_model):
        cfg, params = small_model
        paged = _run_engine(cfg, params, paged=True)
        dense = _run_engine(cfg, params, paged=False)
        # the run must actually exercise the hard paths, or parity is vacuous
        assert paged.paged_decode_ticks > 0, "kernel path never taken"
        assert dense.paged_decode_ticks == 0, "oracle ran the kernel"
        assert paged.suspensions > 0 and paged.prefix_hits > 0
        assert sorted(paged.completed) == sorted(dense.completed)
        for rid in dense.completed:
            assert paged.requests[rid].generated == \
                dense.requests[rid].generated, f"{rid} tokens diverged"

    def test_paged_engine_survives_unpaged_arch(self):
        """An ineligible arch (SSM blocks) silently keeps the dense path
        even when the flag asks for the kernel."""
        cfg = ARCHS["mamba2-2.7b"].smoke()
        params = init_model(cfg, jax.random.PRNGKey(0))
        eng = ServingEngine(
            cfg, params,
            EngineConfig(n_slots=2, max_seq=32,
                         hbm_capacity_bytes=1e12, paged_decode=True),
        )
        eng.submit(Request("r0", "A", list(range(5, 10)), 4))
        eng.run(max_ticks=60)
        assert eng.completed == ["r0"]
        assert eng.paged_decode_ticks == 0


class TestRooflineTickCost:
    def test_costs_are_roofline_derived_and_nonconstant(self, small_model):
        cfg, params = small_model
        eng = _run_engine(cfg, params, paged=True)
        stats = eng.tick_cost_stats()
        assert stats["source"] == "roofline"
        assert stats["ticks"] > 0
        # hand-set constants would collapse to one distinct value
        assert stats["distinct"] > 1
        assert 0.0 < stats["min_s"] <= stats["mean_s"] <= stats["max_s"]
        # seconds at smoke scale: far below the old ~1.0-tick constants
        assert stats["max_s"] < 1e-2

    def test_idle_tick_costs_idle_floor(self, small_model):
        cfg, params = small_model
        eng = ServingEngine(
            cfg, params,
            EngineConfig(n_slots=2, max_seq=32, hbm_capacity_bytes=1e12),
        )
        eng.step()  # nothing submitted: an empty scheduling pass
        assert eng.last_tick_cost == eng._tick_cost_model.idle_s

    def test_cost_model_orders_by_work(self, small_model):
        cfg, _ = small_model
        m = tick_cost_model(cfg, page_tokens=16)
        one = m.tick_seconds(decode_tokens=1)
        four = m.tick_seconds(decode_tokens=4)
        assert 0.0 < one <= four
        # stalls add PCIe traffic on top of the HBM/compute roofline
        stalled = m.tick_seconds(decode_tokens=1, stall_events=2)
        assert stalled > one
        # reading resident KV moves bytes: cost grows with bytes read
        heavy = m.tick_seconds(decode_tokens=1, kv_bytes_read=1e9)
        assert heavy > one


class TestGatherPlan:
    def _mgr(self, pages=8):
        cfg = ARCHS["internlm2-1.8b"]
        page_bytes = kv_bytes_per_token(cfg) * 16
        mgr = PagedKVManager(capacity_bytes=page_bytes * pages,
                             page_tokens=16)
        return cfg, mgr

    def test_provenance_and_pow2_shapes(self):
        cfg, mgr = self._mgr()
        mgr.register("a", cfg)
        mgr.register("b", cfg)
        mgr.grow_to("a", 40)  # 3 pages
        mgr.grow_to("b", 17)  # 2 pages
        tables, src_slot, src_idx, n_pool = mgr.gather_plan(
            ["a", "b"], [0, 1]
        )
        assert tables.shape == (2, 4)  # W = pow2(3) = 4
        assert n_pool & (n_pool - 1) == 0  # power of two
        assert src_slot.shape == (n_pool,) and src_idx.shape == (n_pool,)
        # every referenced page maps back to its owner's slot + index
        for rid, slot in (("a", 0), ("b", 1)):
            for j, pid in enumerate(mgr.page_table(rid)):
                assert src_slot[pid] == slot
                assert src_idx[pid] == j

    def test_width_trims_to_longest_resident(self):
        cfg, mgr = self._mgr()
        mgr.register("long", cfg)
        mgr.register("short", cfg)
        mgr.grow_to("long", 70)  # 5 pages → W = 8
        mgr.grow_to("short", 5)  # 1 page
        tables, _, _, _ = mgr.gather_plan(["long", "short"], [0, 1])
        assert tables.shape[1] == 8

    def test_demoted_pages_raise(self):
        from repro.serve.tiers import TierConfig

        cfg = ARCHS["internlm2-1.8b"]
        page_bytes = kv_bytes_per_token(cfg) * 16
        mgr = PagedKVManager(
            capacity_bytes=page_bytes * 8, page_tokens=16,
            tier_config=TierConfig(host_capacity_bytes=1e9),
        )
        mgr.register("a", cfg)
        mgr.grow_to("a", 40)
        assert mgr.demote_page("a", 0)  # page 0 leaves HBM for host tier
        assert any(p < 0 for p in mgr.page_table("a"))
        with pytest.raises(ValueError, match="demoted"):
            mgr.gather_plan(["a"], [0])
