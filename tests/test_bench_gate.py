"""Benchmark tooling contracts: the regression gate and the runner's
refusal to write partial artifacts (a partial BENCH_serve.json would
silently poison the trajectory the gate trusts)."""

import json
import sys
import types

import pytest

from benchmarks import run as bench_run
from benchmarks.gate import compare, main as gate_main


def _record(p50=10, p99=20, thr=1.5, wins=True, cl_p99=30, cl_wins=True,
            tick_cost="roofline", distinct=8, el_wins=True):
    tc = (
        {"tick_cost": {"source": tick_cost, "distinct": distinct,
                       "ticks": 40, "mean_s": 2e-5}}
        if tick_cost is not None else {}
    )
    return {
        "engine": {
            "murs": {
                "p50_ticks_to_finish": p50,
                "p99_ticks_to_finish": p99,
                "throughput_tokens_per_tick": thr,
            }
        },
        "prefix_cache": {
            "sharing_wins": {
                "hit_rate_positive": wins,
                "peak_pool_lower": wins,
            }
        },
        "cluster": {
            "murs": {
                "p99_ticks_to_finish": cl_p99,
                "throughput_tokens_per_tick": 1.2,
                **tc,
            },
            "cluster_wins": {
                "migration_roundtrip": cl_wins,
                "crash_no_loss": cl_wins,
                "p99_beats_round_robin": cl_wins,
            },
        },
        "elastic": {
            "elastic_wins": {
                "delta_migration_bytes_below_full_copy": el_wins,
                "checkpoint_restore_no_replay_from_zero": el_wins,
                "elastic_goodput_ge_static": el_wins,
            },
        },
    }


class TestGateCompare:
    def test_within_threshold_passes(self):
        rows, failures = compare(_record(), _record(p50=11), 15.0)
        assert not failures
        assert any(r[1] == "p50_ticks_to_finish" for r in rows)

    def test_latency_regression_fails(self):
        _, failures = compare(_record(p50=10), _record(p50=12), 15.0)
        assert any("p50" in f for f in failures)

    def test_throughput_regression_fails_downward_only(self):
        _, failures = compare(_record(thr=1.0), _record(thr=0.8), 15.0)
        assert any("throughput" in f for f in failures)
        _, ok = compare(_record(thr=1.0), _record(thr=2.0), 15.0)
        assert not ok  # faster is never a regression

    def test_none_current_with_numeric_baseline_fails(self):
        _, failures = compare(_record(p50=10), _record(p50=None), 15.0)
        assert any("completed nothing" in f for f in failures)

    def test_sharing_wins_are_hard_gates(self):
        _, failures = compare(_record(), _record(wins=False), 15.0)
        assert any("hit_rate_positive" in f for f in failures)
        assert any("peak_pool_lower" in f for f in failures)

    def test_cluster_p99_gated_like_engine_metrics(self):
        _, failures = compare(_record(), _record(cl_p99=40), 15.0)
        assert any("cluster.murs.p99" in f for f in failures)
        _, ok = compare(_record(), _record(cl_p99=31), 15.0)
        assert not ok  # within ±15%

    def test_cluster_wins_are_hard_gates(self):
        _, failures = compare(_record(), _record(cl_wins=False), 15.0)
        assert any("migration_roundtrip" in f for f in failures)
        assert any("crash_no_loss" in f for f in failures)
        assert any("p99_beats_round_robin" in f for f in failures)

    def test_elastic_wins_are_hard_gates(self):
        _, failures = compare(_record(), _record(el_wins=False), 15.0)
        assert any(
            "delta_migration_bytes_below_full_copy" in f for f in failures
        )
        assert any(
            "checkpoint_restore_no_replay_from_zero" in f for f in failures
        )
        assert any("elastic_goodput_ge_static" in f for f in failures)
        _, ok = compare(_record(), _record(), 15.0)
        assert not ok

    def test_kernel_costs_derived_is_a_hard_gate(self):
        """A serving leg that stops reporting roofline-derived tick
        costs — missing section, wrong source, or a constant value —
        means the loop fell back to hand-set constants: hard FAIL."""
        _, failures = compare(_record(), _record(tick_cost=None), 15.0)
        assert any("no tick_cost" in f for f in failures)
        _, failures = compare(_record(), _record(tick_cost="handset"), 15.0)
        assert any("source='handset'" in f for f in failures)
        _, failures = compare(_record(), _record(distinct=1), 15.0)
        assert any("constant" in f for f in failures)
        _, ok = compare(_record(), _record(), 15.0)
        assert not ok

    def test_missing_baseline_passes_with_notice(self, tmp_path, capsys):
        cur = tmp_path / "cur.json"
        cur.write_text(json.dumps(_record()))
        rc = gate_main(
            ["--current", str(cur), "--baseline", str(tmp_path / "nope.json"),
             "--summary", str(tmp_path / "summary.md")]
        )
        assert rc == 0
        assert "No baseline" in (tmp_path / "summary.md").read_text()

    def test_summary_table_written(self, tmp_path):
        cur = tmp_path / "cur.json"
        base = tmp_path / "base.json"
        cur.write_text(json.dumps(_record(p50=30)))
        base.write_text(json.dumps(_record(p50=10)))
        summary = tmp_path / "summary.md"
        rc = gate_main(
            ["--current", str(cur), "--baseline", str(base),
             "--summary", str(summary)]
        )
        assert rc == 1
        text = summary.read_text()
        assert "| murs | p50_ticks_to_finish | 10 | 30 |" in text
        assert "FAIL" in text


class TestRunnerPartialArtifacts:
    def test_failure_skips_json_and_exits_nonzero(self, tmp_path, monkeypatch):
        """A raising benchmark must exit non-zero WITHOUT writing the
        artifact, even when the serving record itself was produced."""
        fake = types.ModuleType("benchmarks.fake_serve_pressure")
        fake.main = lambda: {"engine": {}}
        monkeypatch.setitem(
            sys.modules, "benchmarks.fake_serve_pressure", fake
        )
        monkeypatch.setattr(
            bench_run,
            "MODULES",
            ["benchmarks.fake_serve_pressure", "benchmarks.does_not_exist"],
        )
        out = tmp_path / "BENCH.json"
        with pytest.raises(SystemExit) as exc:
            bench_run.main(["--json", str(out)])
        assert exc.value.code == 1
        assert not out.exists(), "partial artifact must never be written"

    def test_success_writes_json(self, tmp_path, monkeypatch):
        fake = types.ModuleType("benchmarks.fake_serve_pressure")
        fake.main = lambda: {"engine": {"murs": {}}}
        monkeypatch.setitem(
            sys.modules, "benchmarks.fake_serve_pressure", fake
        )
        monkeypatch.setattr(
            bench_run, "MODULES", ["benchmarks.fake_serve_pressure"]
        )
        out = tmp_path / "BENCH.json"
        bench_run.main(["--json", str(out)])
        assert json.loads(out.read_text()) == {"engine": {"murs": {}}}
