"""Front-door admission control, open-loop traffic, and typed reports.

Covers the overload surface added around the serving engine:

* token-bucket refill arithmetic;
* per-policy ``shed_order`` (fair FIFO, MURS usage-rate, priority weight);
* open-loop trace determinism and validation;
* :class:`ServeReport` round-trip, SLO scoring, and the deprecated dict
  shim;
* the conservation property — every submission a front door ever sees
  ends in exactly one terminal outcome row (hypothesis-driven over a
  lightweight fake server, then end-to-end on the real engine);
* fast vs legacy engine bookkeeping producing identical results.
"""

import jax
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import ARCHS
from repro.models import init_model
from repro.sched import BasePolicy, FairPolicy, MursConfig, MursPolicy
from repro.sched.priority import PriorityConfig, PriorityPolicy
from repro.serve import (
    COMPLETED,
    FAILED,
    LOST,
    RATE_LIMITED,
    SHED,
    UNFINISHED,
    ClusterConfig,
    EngineConfig,
    FrontDoor,
    FrontDoorConfig,
    LatencySummary,
    Request,
    RequestOutcome,
    Server,
    ServeReport,
    ServingCluster,
    ServingEngine,
    SloSpec,
    TenantProfile,
    TokenBucket,
    bursty_trace,
    diurnal_trace,
    drive,
    poisson_trace,
)
from repro.serve.kv_cache import kv_bytes_per_token
from repro.serve.report import percentile


@pytest.fixture(scope="module")
def small_model():
    cfg = ARCHS["internlm2-1.8b"].smoke()
    params = init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


# --------------------------------------------------------------- fake server
class FakeServer:
    """Minimal in-memory :class:`Server`: FIFO queue, ``n_slots``
    concurrent requests, each finishing ``service_ticks`` after it
    starts.  Fast enough to drive thousands of hypothesis examples."""

    def __init__(self, capacity_bytes=0.0, service_ticks=2, n_slots=2,
                 bytes_per_token=10.0):
        self.tick = 0
        self.capacity_bytes = float(capacity_bytes)
        self.service_ticks = service_ticks
        self.n_slots = n_slots
        self.bytes_per_token = bytes_per_token
        self.policy = None
        self.requests = {}
        self.queue = []
        self.active = {}  # rid -> finish tick
        self.done = []

    @property
    def has_pending(self):
        return bool(self.queue or self.active)

    def estimate_request_bytes(self, req):
        return (len(req.prompt) + req.max_new_tokens) * self.bytes_per_token

    def group_demand(self):
        agg = {}
        for rid in list(self.queue) + list(self.active):
            req = self.requests[rid]
            est = self.estimate_request_bytes(req)
            agg[req.tenant] = agg.get(req.tenant, 0.0) + est
        return agg

    def replica_stats(self):
        return {
            "capacity_bytes": self.capacity_bytes,
            "projected_bytes": sum(self.group_demand().values()),
        }

    def submit(self, req):
        self.requests[req.request_id] = req
        req.submit_tick = self.tick
        self.queue.append(req.request_id)
        return True

    def step(self):
        self.tick += 1
        for rid in [r for r, t in self.active.items() if t <= self.tick]:
            del self.active[rid]
            req = self.requests[rid]
            self.done.append(RequestOutcome(
                request_id=rid, tenant=req.tenant, outcome=COMPLETED,
                submit_tick=req.submit_tick, finish_tick=self.tick,
                first_token_tick=req.submit_tick + 1,
                tokens=req.max_new_tokens,
            ))
        while self.queue and len(self.active) < self.n_slots:
            self.active[self.queue.pop(0)] = self.tick + self.service_ticks

    def run(self, max_ticks=1000):
        while self.has_pending and self.tick < max_ticks:
            self.step()
        outcomes = list(self.done)
        for rid in list(self.queue) + list(self.active):
            req = self.requests[rid]
            outcomes.append(RequestOutcome(
                request_id=rid, tenant=req.tenant, outcome=UNFINISHED,
                submit_tick=req.submit_tick,
                reason="still queued at tick budget",
            ))
        rep = ServeReport(policy="fake", submitted=len(self.requests),
                          ticks=self.tick, outcomes=outcomes)
        rep.refresh_summaries()
        rep.apply_slo()
        return rep


# -------------------------------------------------------------- token bucket
class TestTokenBucket:
    def test_starts_full_and_drains(self):
        b = TokenBucket(rate=0.5, burst=2.0)
        assert b.try_take(0.0)
        assert b.try_take(0.0)
        assert not b.try_take(0.0)

    def test_lazy_refill_arithmetic(self):
        b = TokenBucket(rate=0.5, burst=2.0)
        b.try_take(0.0), b.try_take(0.0)  # drain
        # after 2 ticks: tokens = min(2, 0 + 2*0.5) = 1 -> one take only
        assert b.try_take(2.0)
        assert not b.try_take(2.0)
        # after a long gap the bucket caps at burst, not rate*elapsed
        b.try_take(1000.0)
        assert b.tokens == pytest.approx(2.0 - 1.0)

    def test_fractional_rate_epsilon(self):
        # 1/3 per tick accumulates exactly one token every 3 ticks; the
        # epsilon in try_take keeps 0.9999... from failing the >= cost test
        b = TokenBucket(rate=1.0 / 3.0, burst=1.0)
        assert b.try_take(0.0)
        for t in (3.0, 6.0, 9.0):
            assert b.try_take(t), f"refill at t={t} should cover cost 1"
            assert not b.try_take(t)

    def test_cost_above_burst_never_succeeds(self):
        b = TokenBucket(rate=10.0, burst=2.0)
        assert not b.try_take(100.0, cost=3.0)

    def test_zero_rate_never_refills(self):
        b = TokenBucket(rate=0.0, burst=1.0)
        assert b.try_take(0.0)
        assert not b.try_take(10_000.0)

    def test_validation(self):
        with pytest.raises(ValueError):
            TokenBucket(rate=-1.0, burst=1.0)
        with pytest.raises(ValueError):
            TokenBucket(rate=1.0, burst=0.0)


# ---------------------------------------------------------------- shed order
def _stats(**rows):
    """rows: name=(rate, demand_bytes, arrival_seq)"""
    return {
        g: {"rate": r, "demand_bytes": d, "arrival_seq": float(s)}
        for g, (r, d, s) in rows.items()
    }


class TestShedOrder:
    def test_fair_sheds_fifo(self):
        stats = _stats(b=(9.0, 9e9, 1), a=(0.0, 0.0, 0), c=(5.0, 1e6, 2))
        for pol in (BasePolicy(), FairPolicy()):
            assert pol.shed_order(["b", "a", "c"], stats) == ["a", "b", "c"]

    def test_murs_sheds_highest_rate_first(self):
        pol = MursPolicy(MursConfig())
        stats = _stats(a=(1.0, 5e6, 0), b=(8.0, 1e6, 1), c=(3.0, 9e6, 2))
        assert pol.shed_order(["a", "b", "c"], stats) == ["b", "c", "a"]

    def test_murs_warm_ema_overrides_stat_rows(self):
        pol = MursPolicy(MursConfig())
        pol._group_rate = {"a": 9.0, "b": 1.0}
        # the stats rows say b is hotter, but the policy's own EMA wins
        stats = _stats(a=(0.0, 0.0, 0), b=(99.0, 0.0, 1))
        assert pol.shed_order(["a", "b"], stats) == ["a", "b"]

    def test_murs_cold_start_falls_back_to_demand(self):
        pol = MursPolicy(MursConfig())
        stats = _stats(a=(0.0, 1e6, 0), b=(0.0, 8e6, 1), c=(0.0, 4e6, 2))
        assert pol.shed_order(["a", "b", "c"], stats) == ["b", "c", "a"]

    def test_priority_sheds_lowest_weight_first(self):
        pol = PriorityPolicy(PriorityConfig(weights={"gold": 4.0, "low": 0.5}))
        stats = _stats(gold=(9.0, 9e9, 0), free=(0.0, 0.0, 1),
                       low=(0.0, 0.0, 2))
        # low (0.5) < free (default 1.0) < gold (4.0); rate is ignored
        assert pol.shed_order(["gold", "free", "low"], stats) == [
            "low", "free", "gold",
        ]

    def test_priority_ties_break_fifo(self):
        pol = PriorityPolicy()
        stats = _stats(y=(0.0, 0.0, 1), x=(0.0, 0.0, 0))
        assert pol.shed_order(["y", "x"], stats) == ["x", "y"]


# ------------------------------------------------------------------- traffic
TENANTS = (
    TenantProfile("interactive", weight=3.0, prompt_tokens=(2, 6),
                  output_tokens=(2, 8)),
    TenantProfile("batch", weight=1.0, prompt_tokens=(8, 16),
                  output_tokens=(16, 32)),
)


def _sig(trace):
    return [
        (a.tick, a.request.request_id, tuple(a.request.prompt),
         a.request.max_new_tokens)
        for a in trace
    ]


class TestTraffic:
    def test_same_seed_same_trace(self):
        kw = dict(rate_per_tick=0.5, n_requests=200, seed=7)
        assert _sig(poisson_trace(TENANTS, **kw)) == _sig(
            poisson_trace(TENANTS, **kw)
        )

    def test_seed_changes_trace(self):
        a = poisson_trace(TENANTS, rate_per_tick=0.5, n_requests=50, seed=1)
        b = poisson_trace(TENANTS, rate_per_tick=0.5, n_requests=50, seed=2)
        assert _sig(a) != _sig(b)

    def test_traces_are_sorted_and_sized(self):
        for trace in (
            poisson_trace(TENANTS, rate_per_tick=1.0, n_requests=300, seed=3),
            diurnal_trace(TENANTS, base_rate_per_tick=1.0, n_requests=300,
                          seed=3),
            bursty_trace(TENANTS, rate_per_tick=1.0, n_requests=300, seed=3),
        ):
            assert len(trace) == 300
            ticks = [a.tick for a in trace]
            assert ticks == sorted(ticks)

    def test_weights_shape_the_mix(self):
        trace = poisson_trace(TENANTS, rate_per_tick=1.0, n_requests=400,
                              seed=11)
        n_interactive = sum(
            1 for a in trace if a.request.tenant == "interactive"
        )
        assert n_interactive > 400 - n_interactive  # 3:1 weights

    def test_validation(self):
        with pytest.raises(ValueError):
            poisson_trace((), rate_per_tick=1.0, n_requests=1)
        with pytest.raises(ValueError):
            poisson_trace(TENANTS, rate_per_tick=0.0, n_requests=1)
        with pytest.raises(ValueError):
            diurnal_trace(TENANTS, base_rate_per_tick=1.0, n_requests=1,
                          amplitude=1.5)
        with pytest.raises(ValueError):
            bursty_trace(TENANTS, rate_per_tick=1.0, n_requests=1,
                         burst_factor=0.5)


# -------------------------------------------------------------- serve report
class TestServeReport:
    def _report(self):
        rep = ServeReport(policy="murs", submitted=3, ticks=10)
        rep.outcomes = [
            RequestOutcome("a", "T", COMPLETED, submit_tick=0, finish_tick=4,
                           first_token_tick=1, tokens=4),
            RequestOutcome("b", "T", COMPLETED, submit_tick=0, finish_tick=9,
                           first_token_tick=6, tokens=4),
            RequestOutcome("c", "U", SHED, submit_tick=2, finish_tick=2,
                           reason="projected demand over threshold"),
        ]
        rep.refresh_summaries()
        return rep

    def test_percentile_nearest_rank(self):
        assert percentile([], 0.5) is None
        assert percentile([7.0], 0.99) == 7.0
        vals = list(range(1, 101))
        assert percentile(vals, 0.50) == 51  # nearest-rank on 0..99 index
        assert percentile(vals, 0.99) == 99
        assert percentile(vals, 1.0) == 100

    def test_refresh_counts_and_latency(self):
        rep = self._report()
        assert (rep.completed, rep.shed, rep.failed) == (2, 1, 0)
        assert rep.latency.count == 2 and rep.latency.mean == 6.5
        assert rep.ttft.p50 in (1, 6)

    def test_slo_scoring_gates_goodput(self):
        rep = self._report()
        rep.apply_slo({"T": SloSpec(ttft_ticks=2.0)})
        assert rep.slo_good == 1  # only "a" met TTFT <= 2
        assert rep.goodput == pytest.approx(1 / 10)
        rep.apply_slo()  # no SLO: every completion is good
        assert rep.slo_good == 2

    def test_slo_skips_unmeasured_dimensions(self):
        spec = SloSpec(ttft_ticks=1.0, latency_ticks=100.0)
        row = RequestOutcome("x", "T", COMPLETED, submit_tick=0,
                             finish_tick=50)  # no first_token_tick
        assert spec.met(row)  # TTFT unmeasured -> skipped, latency ok
        assert not spec.met(
            RequestOutcome("y", "T", FAILED, finish_tick=1)
        )

    def test_json_round_trip(self):
        rep = self._report()
        rep.apply_slo({"T": SloSpec(latency_ticks=100.0)})
        back = ServeReport.from_json(rep.to_json(include_outcomes=True))
        assert back.json_str(include_outcomes=True) == rep.json_str(
            include_outcomes=True
        )
        assert back.outcomes[2].reason == rep.outcomes[2].reason

    def test_dict_shim_removed(self):
        """The one-release ``__getitem__`` compat shim is gone: legacy
        keys are reached explicitly through ``.extras`` only."""
        rep = self._report()
        rep.extras = {"completed": 2, "ticks": 10}
        with pytest.raises(TypeError):
            rep["completed"]
        assert not hasattr(rep, "get")
        assert not hasattr(rep, "keys")
        assert rep.extras["completed"] == 2

    def test_tenant_summary(self):
        assert self._report().tenant_summary() == {
            "T": {COMPLETED: 2},
            "U": {SHED: 1},
        }


# ------------------------------------------------------------ conservation
TERMINAL = {COMPLETED, FAILED, SHED, RATE_LIMITED, LOST, UNFINISHED}


def _assert_conserved(report, n_submitted):
    """Every submission -> exactly one terminal outcome row."""
    assert report.submitted == n_submitted
    assert len(report.outcomes) == n_submitted
    ids = [o.request_id for o in report.outcomes]
    assert len(set(ids)) == len(ids), "duplicate outcome rows"
    by_outcome = {}
    for o in report.outcomes:
        assert o.outcome in TERMINAL, o.outcome
        if o.outcome != COMPLETED:
            assert o.reason, f"non-completion without a reason: {o}"
        by_outcome[o.outcome] = by_outcome.get(o.outcome, 0) + 1
    assert sum(by_outcome.values()) == n_submitted


class TestConservation:
    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(min_value=0, max_value=10_000),
        rate_x10=st.integers(min_value=1, max_value=30),
        capacity=st.sampled_from([0.0, 400.0, 2_000.0, 1e9]),
        bucket_rate_x10=st.sampled_from([None, 1, 5, 50]),
        policy_name=st.sampled_from(["fair", "murs", "priority"]),
    )
    def test_every_submission_gets_one_outcome(
        self, seed, rate_x10, capacity, bucket_rate_x10, policy_name
    ):
        policy = {
            "fair": FairPolicy,
            "murs": lambda: MursPolicy(MursConfig()),
            "priority": PriorityPolicy,
        }[policy_name]()
        door = FrontDoor(
            FakeServer(capacity_bytes=capacity),
            FrontDoorConfig(
                pressure_threshold=0.9,
                default_bucket=(
                    None if bucket_rate_x10 is None
                    else (bucket_rate_x10 / 10.0, 2.0)
                ),
                policy=policy,
            ),
        )
        trace = poisson_trace(
            TENANTS, rate_per_tick=rate_x10 / 10.0, n_requests=60, seed=seed
        )
        report = drive(door, trace, max_ticks=5_000)
        _assert_conserved(report, 60)

    def test_unlimited_door_is_transparent(self):
        door = FrontDoor(FakeServer())
        trace = poisson_trace(TENANTS, rate_per_tick=0.5, n_requests=40,
                              seed=5)
        report = drive(door, trace, max_ticks=5_000)
        _assert_conserved(report, 40)
        assert report.completed == 40
        assert report.shed == 0 and report.rate_limited == 0

    def test_murs_door_sheds_hot_tenant_under_pressure(self):
        """At a tight capacity the usage-rate order concentrates rejects
        on the tenant growing the pool fastest (frequent AND heavy)
        rather than spraying them FIFO."""
        tenants = (
            TenantProfile("light", weight=1.0, prompt_tokens=(2, 4),
                          output_tokens=(2, 4)),
            TenantProfile("heavy", weight=2.0, prompt_tokens=(8, 16),
                          output_tokens=(24, 48)),
        )
        door = FrontDoor(
            FakeServer(capacity_bytes=600.0, service_ticks=8, n_slots=1),
            FrontDoorConfig(pressure_threshold=0.8,
                            policy=MursPolicy(MursConfig())),
        )
        trace = poisson_trace(tenants, rate_per_tick=2.0, n_requests=120,
                              seed=9)
        report = drive(door, trace, max_ticks=5_000)
        _assert_conserved(report, 120)
        assert report.shed > 0
        shed_by = report.extras["shed_by_tenant"]
        assert shed_by.get("heavy", 0) > shed_by.get("light", 0)


# ----------------------------------------------------------- server protocol
class TestServerProtocol:
    def test_fake_and_door_conform(self):
        fake = FakeServer()
        assert isinstance(fake, Server)
        assert isinstance(FrontDoor(fake), Server)

    def test_engine_and_cluster_conform(self, small_model):
        cfg, params = small_model
        eng = ServingEngine(cfg, params, EngineConfig(
            n_slots=2, max_seq=64, hbm_capacity_bytes=1e9))
        assert isinstance(eng, Server)
        cl = ServingCluster(cfg, params, ClusterConfig(
            engine=lambda: EngineConfig(
                n_slots=2, max_seq=64, hbm_capacity_bytes=1e9),
            n_replicas=2))
        assert isinstance(cl, Server)


# ------------------------------------------------- real-engine integration
class TestEngineIntegration:
    def test_frontdoor_over_real_engine_conserves(self, small_model):
        cfg, params = small_model
        cap = kv_bytes_per_token(cfg) * 96
        eng = ServingEngine(cfg, params, EngineConfig(
            n_slots=2, max_seq=64, hbm_capacity_bytes=cap,
            policy=MursPolicy(MursConfig.for_serving(period=1.0))))
        door = FrontDoor(eng, FrontDoorConfig(pressure_threshold=0.9))
        trace = poisson_trace(TENANTS, rate_per_tick=0.8, n_requests=30,
                              seed=13)
        report = drive(door, trace, max_ticks=400)
        _assert_conserved(report, 30)
        assert report.completed > 0
        assert report.goodput > 0.0

    def test_fast_and_legacy_bookkeeping_agree(self, small_model):
        cfg, params = small_model
        cap = kv_bytes_per_token(cfg) * 80

        def run(legacy):
            eng = ServingEngine(cfg, params, EngineConfig(
                n_slots=2, max_seq=64, hbm_capacity_bytes=cap,
                policy=MursPolicy(MursConfig.for_serving(period=1.0)),
                legacy_bookkeeping=legacy))
            for i in range(3):
                eng.submit(Request(f"A{i}", "A", list(range(10, 18)), 40))
            for i in range(4):
                eng.submit(Request(f"B{i}", "B", list(range(30, 34)), 6))
            rep = eng.run(max_ticks=200)
            return rep.extras, eng.replica_stats()

        legacy_extras, legacy_stats = run(True)
        fast_extras, fast_stats = run(False)
        assert fast_extras == legacy_extras
        assert fast_stats == legacy_stats
