"""End-to-end dist integration on the 1×1 debug mesh.

The dry-run launcher composes presets → rules → param/batch shardings →
jit with in_shardings, with the model's ``shard()`` constraints traced
inside ``use_rules``.  That composition never runs in the substrate unit
tests, so exercise it here on a CPU-sized smoke config.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import SHAPES, ShapeConfig
from repro.dist.presets import arch_overrides, batch_shardings
from repro.dist.sharding import (
    current_rules,
    make_rules,
    param_shardings,
    shard,
    use_rules,
)
from repro.launch.mesh import make_debug_mesh
from repro.launch.specs import input_specs
from repro.models import init_model
from repro.optim import adamw
from repro.train.train_step import make_train_step


def test_shard_is_identity_without_rules():
    x = jnp.ones((2, 3))
    assert current_rules() is None
    assert shard(x, ("batch", None)) is x


def test_shard_unknown_logical_axis_fails_loudly():
    rules = make_rules(make_debug_mesh())
    with use_rules(rules):
        with pytest.raises(KeyError, match="unknown logical axis"):
            shard(jnp.ones((2,)), ("batcj",))


def test_train_step_under_rules_matches_unsharded():
    cfg = ARCHS["internlm2-1.8b"].smoke()
    shape = ShapeConfig("t", 16, 4, "train")
    mesh = make_debug_mesh()
    rules = make_rules(mesh, overrides=arch_overrides(cfg, mesh, shape))

    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    opt_state = adamw.init(params)
    batch = {
        "tokens": jax.random.randint(key, (4, 16), 0, cfg.vocab),
        "labels": jax.random.randint(key, (4, 16), 0, cfg.vocab),
    }
    step_fn = make_train_step(cfg, adamw.AdamWConfig(lr=1e-3))

    _, _, plain = jax.jit(step_fn)(params, opt_state, batch)

    p_shard = param_shardings(params, rules)
    b_shard = batch_shardings(cfg, rules, batch)
    o_shard = adamw.AdamWState(
        step=rules.sharding(()),
        m=param_shardings(params, rules),
        v=param_shardings(params, rules),
    )
    with use_rules(rules):
        jitted = jax.jit(step_fn, in_shardings=(p_shard, o_shard, b_shard))
        _, _, sharded = jitted(params, opt_state, batch)

    np.testing.assert_allclose(
        float(plain["loss"]), float(sharded["loss"]), rtol=1e-5
    )


def test_arch_overrides_cover_all_configs():
    """Every (arch × applicable shape) cell must resolve to valid rules."""
    mesh = make_debug_mesh()
    for cfg in ARCHS.values():
        for shape_name in cfg.applicable_shapes:
            shape = SHAPES[shape_name]
            rules = make_rules(
                mesh, overrides=arch_overrides(cfg, mesh, shape)
            )
            # decode/prefill/train input specs all resolve to shardings
            specs = input_specs(cfg.smoke(), shape)
            shardings = batch_shardings(cfg.smoke(), rules, specs)
            for leaf in jax.tree_util.tree_leaves(
                shardings, is_leaf=lambda x: hasattr(x, "spec")
            ):
                assert hasattr(leaf, "spec")
