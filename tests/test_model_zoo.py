"""Heterogeneous model-zoo serving: specs, byte models, capability routing.

The multi-layer refactor made architecture a first-class dimension —
every request/slot/replica/policy decision keys on an explicit
``ModelSpec`` derived from ``ArchConfig`` (DESIGN.md §12).  This suite
pins the three layers the refactor touched:

* **configs** — every architecture in the zoo constructs, declares a
  valid memory class, and exposes a non-negative byte model monotone in
  context length (the satellite smoke over all ten configs);
* **cluster** — capability routing: a request only lands on a replica
  hosting its model, a request nobody hosts fails TYPED (never a
  division error or a silent drop), and an all-parked fleet either
  revives (autoscale) or fails typed too;
* **engine** — the ``wrong_model`` typed failure and the int8 paged
  decode flag (``paged_decode_int8``), with the f32 path as the
  differential oracle for completion behavior.
"""

import jax
import pytest

from repro.configs import ARCHS, MEMORY_CLASSES, ModelSpec
from repro.models import init_model
from repro.sched import FairPolicy, MursConfig, MursPolicy
from repro.serve import (
    ClusterConfig,
    EngineConfig,
    Request,
    ServingCluster,
    ServingEngine,
)
from repro.serve.kv_cache import kv_bytes_per_token

ALL_ARCHS = sorted(ARCHS)

#: the declared class each architecture's byte model must induce —
#: drift here means the byte model itself changed (DESIGN.md §12 table)
EXPECTED_CLASS = {
    "deepseek-v2-236b": "paged_kv",
    "gemma3-1b": "paged_kv",
    "granite-moe-3b-a800m": "paged_kv",
    "internlm2-1.8b": "paged_kv",
    "internvl2-26b": "paged_kv",
    "mamba2-2.7b": "constant_state",
    "qwen1.5-110b": "paged_kv",
    "stablelm-1.6b": "paged_kv",
    "whisper-base": "encoder_decoder",
    "zamba2-1.2b": "paged_kv",
}


# --------------------------------------------------------------- configs
@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_spec_constructs_and_classifies(arch):
    """Every zoo architecture yields a frozen ModelSpec with a declared
    memory class from the closed vocabulary."""
    cfg = ARCHS[arch].smoke()
    spec = cfg.spec()
    assert isinstance(spec, ModelSpec)
    assert spec.arch == cfg.name
    assert spec.memory_class in MEMORY_CLASSES
    assert spec.memory_class == cfg.memory_class()
    assert spec.memory_class == EXPECTED_CLASS[arch]


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_byte_model_non_negative_and_monotone(arch):
    """context_bytes is >= 0 everywhere and non-decreasing in context
    length — admission estimates must never shrink as a request grows."""
    cfg = ARCHS[arch].smoke()
    assert cfg.kv_bytes_per_token() >= 0.0
    assert cfg.constant_state_bytes() >= 0.0
    assert cfg.encoder_bytes(0) == 0.0
    assert cfg.encoder_bytes(16) >= 0.0
    lengths = [0, 1, 16, 64, 256, 4096]
    values = [cfg.context_bytes(n) for n in lengths]
    assert all(v >= 0.0 for v in values)
    assert all(a <= b for a, b in zip(values, values[1:]))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_grows_with_context_matches_class(arch):
    """The one-bit summary agrees with the declared class: flat classes
    have zero marginal bytes, growing classes nonzero."""
    spec = ARCHS[arch].smoke().spec()
    if spec.memory_class in ("constant_state", "zero_kv"):
        assert not spec.grows_with_context
        assert spec.kv_bytes_per_token == 0.0
    else:
        assert spec.grows_with_context


def test_encoder_bytes_only_for_encoder_decoder():
    """Encoder bytes are nonzero exactly for encoder–decoder archs, and
    scale with the prompt (whisper pays its cross-KV at admission)."""
    whisper = ARCHS["whisper-base"].smoke()
    assert whisper.encoder_bytes(8) > 0.0
    assert whisper.encoder_bytes(64) >= whisper.encoder_bytes(8)
    for arch in ALL_ARCHS:
        if arch == "whisper-base":
            continue
        assert ARCHS[arch].smoke().encoder_bytes(64) == 0.0


# --------------------------------------------------------------- engine
@pytest.fixture(scope="module")
def small_model():
    cfg = ARCHS["internlm2-1.8b"].smoke()
    params = init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def mamba_model():
    cfg = ARCHS["mamba2-2.7b"].smoke()
    params = init_model(cfg, jax.random.PRNGKey(1))
    return cfg, params


def _ecfg(cfg, **over):
    kw = dict(
        n_slots=2, max_seq=64,
        hbm_capacity_bytes=kv_bytes_per_token(cfg) * 80
        + cfg.constant_state_bytes() * 4,
        policy=FairPolicy(),
    )
    kw.update(over)
    return EngineConfig(**kw)


def test_engine_rejects_wrong_model_typed(small_model):
    """A request targeting a different arch fails TYPED at submit: it
    never enters the live set, counts a misroute, and keeps conservation
    (exactly one terminal outcome)."""
    cfg, params = small_model
    eng = ServingEngine(cfg, params, _ecfg(cfg))
    ok = eng.submit(Request("wm0", "T", [1, 2, 3], 4, model="some-other-arch"))
    assert ok  # accepted INTO the outcome ledger, not into the batch
    eng.submit(Request("ok0", "T", [1, 2, 3], 4))
    rep = eng.run(max_ticks=100)
    assert eng.misroutes == 1
    rows = {o.request_id: o for o in rep.outcomes}
    assert rows["wm0"].outcome == "failed"
    assert rows["wm0"].reason.startswith("wrong_model:")
    assert rows["wm0"].model == "some-other-arch"
    assert rows["ok0"].outcome == "completed"
    assert rows["ok0"].model == cfg.name


def test_engine_stats_declare_model_and_class(small_model, mamba_model):
    """replica_stats carries the hosted model and its memory class — the
    routing and scaling signal for heterogeneous fleets."""
    for cfg, params in (small_model, mamba_model):
        eng = ServingEngine(cfg, params, _ecfg(cfg))
        stats = eng.replica_stats()
        assert stats["model"] == cfg.name
        assert stats["memory_class"] == cfg.memory_class()


def test_paged_decode_int8_flag(small_model):
    """The int8 paged-decode flag runs the quantized kernel on the same
    hot path: same completion set as the f32 oracle engine, and the
    int8 tick counter proves the quantized kernel actually ran."""
    cfg, params = small_model
    arrivals = [
        Request(f"r{i}", "T", list(range(4 + i, 12 + i)), 6)
        for i in range(3)
    ]

    def run(int8):
        eng = ServingEngine(
            cfg, params, _ecfg(cfg, n_slots=3, paged_decode_int8=int8)
        )
        for req in arrivals:
            eng.submit(
                Request(req.request_id, req.tenant, list(req.prompt),
                        req.max_new_tokens)
            )
        rep = eng.run(max_ticks=200)
        return eng, rep

    f32_eng, f32_rep = run(False)
    i8_eng, i8_rep = run(True)
    assert f32_eng.paged_int8_ticks == 0
    assert i8_eng.paged_int8_ticks > 0
    assert i8_rep.completed == f32_rep.completed == len(arrivals)


# -------------------------------------------------------------- cluster
def _ccfg(cfg, n_replicas, **over):
    kw = dict(
        engine=lambda: _ecfg(
            cfg, policy=MursPolicy(MursConfig.for_serving(period=1.0))
        ),
        n_replicas=n_replicas,
        net_bytes_per_tick=kv_bytes_per_token(cfg) * 16,
    )
    kw.update(over)
    return ClusterConfig(**kw)


def test_cluster_routes_by_capability(small_model, mamba_model):
    """On a mixed fleet every request lands only on a replica hosting
    its model: zero engine misroutes, per-model outcome rows."""
    tcfg, tparams = small_model
    mcfg, mparams = mamba_model
    cl = ServingCluster(
        tcfg, tparams, _ccfg(tcfg, 2),
        models=[(tcfg, tparams), (mcfg, mparams)],
    )
    assert cl.hosted_models() == [tcfg.name, mcfg.name]
    for i in range(3):
        cl.submit(Request(f"t{i}", "T", [1, 2, 3], 4, model=tcfg.name))
        cl.submit(Request(f"m{i}", "M", [5, 6, 7], 4, model=mcfg.name))
    rep = cl.run(max_ticks=300)
    assert rep.completed == 6
    assert rep.extras["misroutes"] == 0
    assert rep.extras["unroutable"] == 0
    per = rep.model_summary()
    assert per[tcfg.name]["completed"] == 3
    assert per[mcfg.name]["completed"] == 3


def test_cluster_unroutable_model_fails_typed(small_model):
    """A request whose model NO replica hosts fails typed — a terminal
    outcome with an ``unroutable:`` reason, never an exception or a
    silent drop; routable traffic is unaffected."""
    cfg, params = small_model
    cl = ServingCluster(cfg, params, _ccfg(cfg, 2))
    cl.submit(Request("x0", "X", [1, 2], 3, model="no-such-arch"))
    cl.submit(Request("ok0", "T", [1, 2, 3], 4))
    rep = cl.run(max_ticks=200)
    rows = {o.request_id: o for o in rep.outcomes}
    assert rows["x0"].outcome == "failed"
    assert rows["x0"].reason.startswith("unroutable:")
    assert rows["x0"].model == "no-such-arch"
    assert rows["ok0"].outcome == "completed"
    assert rep.extras["unroutable"] == 1
    # conservation: every submission got exactly one outcome row
    assert len(rep.outcomes) == 2


def test_cluster_all_parked_fails_typed_without_autoscale(small_model):
    """An all-parked static fleet cannot serve: submissions fail typed
    instead of dividing by an empty score set or hanging forever."""
    cfg, params = small_model
    cl = ServingCluster(cfg, params, _ccfg(cfg, 2))
    for i in list(cl._active_indices()):
        cl._park(i)
    cl.submit(Request("p0", "T", [1, 2, 3], 4))
    rep = cl.run(max_ticks=100)
    rows = {o.request_id: o for o in rep.outcomes}
    assert rows["p0"].outcome == "failed"
    assert rows["p0"].reason.startswith("unroutable:")


def test_cluster_all_parked_revives_with_autoscale(small_model):
    """The same all-parked fleet WITH autoscaling revives a capable
    replica instead of failing the request."""
    cfg, params = small_model
    cl = ServingCluster(
        cfg, params,
        _ccfg(cfg, 2, autoscale=True, min_replicas=1, max_replicas=2,
              scale_sustain_ticks=2, scale_cooldown_ticks=2),
    )
    for i in list(cl._active_indices()):
        cl._park(i)
    cl.submit(Request("rv0", "T", [1, 2, 3], 4))
    rep = cl.run(max_ticks=200)
    rows = {o.request_id: o for o in rep.outcomes}
    assert rows["rv0"].outcome == "completed"
    assert cl.scale_ups >= 1


def test_cluster_migration_refuses_cross_arch_target(small_model,
                                                     mamba_model):
    """migrate() refuses to export when the only other replica hosts a
    different arch — the request's sole state copy is never stranded."""
    tcfg, tparams = small_model
    mcfg, mparams = mamba_model
    cl = ServingCluster(
        tcfg, tparams, _ccfg(tcfg, 2),
        models=[(tcfg, tparams), (mcfg, mparams)],
    )
    cl.submit(Request("h0", "T", list(range(8)), 24, model=tcfg.name))
    for _ in range(6):
        cl.step()
    live = [
        rid for rid, r in cl.replicas[0].requests.items()
        if r.state not in ("done", "failed")
    ]
    assert live, "request should be running on replica 0"
    assert cl.migrate(live[0], 0) is False
    rep = cl.run(max_ticks=300)
    rows = {o.request_id: o for o in rep.outcomes}
    assert rows["h0"].outcome == "completed"


def test_cluster_models_length_mismatch_raises(small_model):
    """A models list that does not match n_replicas is a config error."""
    cfg, params = small_model
    with pytest.raises(ValueError):
        ServingCluster(
            cfg, params, _ccfg(cfg, 2), models=[(cfg, params)]
        )
