"""Import sweep: every module under ``repro.*`` must import cleanly.

The seed repo shipped with model/trainer/launch modules importing a
``repro.dist`` package that did not exist, which killed the whole suite at
collection time with an opaque mid-collection error.  This sweep turns any
future missing-module regression into a single parametrized failure naming
the exact module.
"""

import importlib
import pkgutil

import pytest

import repro


def _all_modules():
    names = ["repro"]
    for mod in pkgutil.walk_packages(repro.__path__, prefix="repro."):
        names.append(mod.name)
    return sorted(names)


@pytest.mark.parametrize("name", _all_modules())
def test_module_imports(name):
    importlib.import_module(name)


def test_sweep_covers_known_subsystems():
    """The walk must actually see the package tree (guards against the
    sweep silently passing on an empty/namespace-mangled layout)."""
    mods = set(_all_modules())
    for required in (
        "repro.dist.sharding",
        "repro.dist.compression",
        "repro.dist.fault",
        "repro.dist.presets",
        "repro.models.transformer",
        "repro.train.trainer",
        "repro.serve.engine",
        "repro.launch.dryrun",
        "repro.kernels.flash_attention",
    ):
        assert required in mods, f"import sweep lost {required}"
