"""Pytest bootstrap for the src layout + hermetic-container dep gating.

Two jobs, both no-ops in a fully provisioned environment (CI):

1. make ``repro`` importable from ``src/`` when the package is not
   installed (so plain ``pytest`` works without the ``PYTHONPATH=src``
   incantation — which also keeps working);
2. when the real ``hypothesis`` package is absent, install a minimal
   deterministic fallback so the property tests still run: each ``@given``
   test executes ``max_examples`` seeded-random draws (boundary values
   first).  This is a *gate* for containers where nothing can be
   installed, not a replacement — CI installs real hypothesis.
"""

import functools
import inspect
import os
import random
import sys
import types
import zlib

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "src")
if os.path.isdir(_SRC) and _SRC not in sys.path:
    sys.path.insert(0, _SRC)


def _install_hypothesis_fallback():
    class Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rnd, i):
            return self._draw(rnd, i)

    def floats(min_value=0.0, max_value=1.0, **_):
        bounds = (min_value, max_value)

        def draw(rnd, i):
            if i < 2:
                return bounds[i]
            return rnd.uniform(min_value, max_value)

        return Strategy(draw)

    def integers(min_value=0, max_value=10, **_):
        bounds = (min_value, max_value)

        def draw(rnd, i):
            if i < 2:
                return bounds[i]
            return rnd.randint(min_value, max_value)

        return Strategy(draw)

    def booleans():
        return Strategy(lambda rnd, i: (i % 2 == 0) if i < 2 else rnd.random() < 0.5)

    def sampled_from(elements):
        seq = list(elements)
        return Strategy(lambda rnd, i: seq[i % len(seq)] if i < len(seq) else rnd.choice(seq))

    def lists(elements, min_size=0, max_size=10, **_):
        def draw(rnd, i):
            size = min_size if i == 0 else rnd.randint(min_size, max_size)
            return [elements.example(rnd, rnd.randint(2, 10**6)) for _ in range(size)]

        return Strategy(draw)

    def tuples(*strategies):
        return Strategy(
            lambda rnd, i: tuple(s.example(rnd, i) for s in strategies)
        )

    def settings(max_examples=20, **_):
        def deco(fn):
            fn._fallback_settings = {"max_examples": max_examples}
            return fn

        return deco

    def given(*pos_strategies, **strategies):
        def deco(fn):
            inner = getattr(fn, "_fallback_settings", {})

            @functools.wraps(fn)
            def wrapper(*a, **kw):
                cfg = getattr(wrapper, "_fallback_settings", inner)
                n = cfg.get("max_examples", 20)
                seed = zlib.crc32(fn.__qualname__.encode())
                rnd = random.Random(seed)
                for i in range(n):
                    pos = tuple(s.example(rnd, i) for s in pos_strategies)
                    example = {
                        k: s.example(rnd, i) for k, s in strategies.items()
                    }
                    fn(*a, *pos, **kw, **example)

            # hide the strategy-filled params from pytest's fixture
            # resolution (real hypothesis does the same)
            params = list(inspect.signature(fn).parameters.values())
            if pos_strategies:
                start = 1 if params and params[0].name == "self" else 0
                del params[start : start + len(pos_strategies)]
            params = [p for p in params if p.name not in strategies]
            wrapper.__signature__ = inspect.Signature(params)
            if hasattr(wrapper, "__wrapped__"):
                del wrapper.__wrapped__
            return wrapper

        return deco

    def assume(condition):
        if not condition:
            raise AssertionError("hypothesis fallback: assume() failed")

    hyp = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    for name, obj in (
        ("floats", floats),
        ("integers", integers),
        ("booleans", booleans),
        ("sampled_from", sampled_from),
        ("lists", lists),
        ("tuples", tuples),
    ):
        setattr(st, name, obj)
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.strategies = st
    hyp.__version__ = "0.0-fallback"
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_fallback()
